"""Recovery drill: MTTR and chaos overhead of the fault-tolerance layer.

Runs the qwen3-0.6b smoke config clean and under a seeded chaos plan
(crash + slowdown + ckpt-write failures + preemption,
docs/robustness.md) with the recovery supervisor, and measures what the
paper's robustness argument actually costs:

* **MTTR** — wall-clock seconds from the crash to the restored Trainer
  resuming (``run_supervised``'s ``recover_times``, which includes the
  rebuild, the checkpoint walk-back/restore, and the injector resync);
* **chaos overhead** — supervised-chaos wall time over the fault-free
  wall time (recomputed steps + recovery machinery);
* **loss delta** — final loss under chaos minus fault-free (the
  acceptance bar: recovery must not change what is learned).

Writes experiments/bench/BENCH_recovery.json and mirrors the headline
summary (mttr_s, chaos_overhead_x, loss_delta) to the repo-root
BENCH_recovery.json for the perf-trajectory tooling.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import tiny_lm_config, write_bench

SPEC = "crash@5:w1,slow@3:w0,ckpt_io@7,preempt@10"


def _cfg(ckpt_dir: str, steps: int, spec: str = ""):
    from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                    FaultConfig, OptimizerConfig,
                                    ShapeConfig, TrainConfig)
    return TrainConfig(
        model=tiny_lm_config(),
        shape=ShapeConfig("bench", 8, 12, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=4,
                                      backup_workers=2),
        optimizer=OptimizerConfig(name="sgd", learning_rate=0.1,
                                  scale_lr_with_workers=False),
        checkpoint=CheckpointConfig(directory=ckpt_dir, every_steps=4),
        seed=0, total_steps=steps, chunk_size=4, log_every=4,
        faults=FaultConfig(spec=spec, seed=7))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="short run (CI canary settings)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args(argv)
    steps = args.steps or (16 if args.quick else 48)

    import tempfile

    from repro.core.straggler import Uniform
    from repro.train.loop import run_experiment
    from repro.train.supervisor import run_supervised

    lat = Uniform(1.0, 2.0)
    with tempfile.TemporaryDirectory() as td:
        # warm the jit caches so neither arm pays first-compile
        run_experiment(_cfg(os.path.join(td, "w"), min(steps, 8)),
                       latency=lat)

        t0 = time.perf_counter()
        clean = run_experiment(_cfg(os.path.join(td, "clean"), steps),
                               latency=lat)
        clean_s = time.perf_counter() - t0

        recover_times = []
        t0 = time.perf_counter()
        chaos = run_supervised(_cfg(os.path.join(td, "chaos"), steps, SPEC),
                               latency=lat, recover_times=recover_times)
        chaos_s = time.perf_counter() - t0

    loss_delta = chaos.metrics[-1]["loss"] - clean.metrics[-1]["loss"]
    mttr = (sum(recover_times) / len(recover_times)) if recover_times else 0.0
    events = [e["event"] for e in chaos.recovery_log]
    results = [{"arm": "clean", "steps": clean.steps, "wall_s": clean_s,
                "final_loss": clean.metrics[-1]["loss"]},
               {"arm": "chaos", "steps": chaos.steps, "wall_s": chaos_s,
                "final_loss": chaos.metrics[-1]["loss"],
                "restores": events.count("restore"),
                "recovery_events": len(chaos.recovery_log)}]
    payload = {
        "bench": "recovery",
        "model": "qwen3-0.6b smoke",
        "steps": steps,
        "fault_spec": SPEC,
        "results": results,
        "mttr_s": mttr,
        "chaos_overhead_x": chaos_s / clean_s,
        "loss_delta": loss_delta,
    }
    mirror = {"bench": "recovery", "fault_spec": SPEC,
              "mttr_s": mttr, "chaos_overhead_x": payload["chaos_overhead_x"],
              "loss_delta": loss_delta}
    path = write_bench("BENCH_recovery", payload, mirror=mirror)

    for r in results:
        print(f"arm={r['arm']:<6} steps={r['steps']:>3} "
              f"wall {r['wall_s']:6.2f}s final_loss {r['final_loss']:.4f}")
    print(f"MTTR {mttr:.2f}s, chaos overhead "
          f"{payload['chaos_overhead_x']:.2f}x, loss delta "
          f"{loss_delta:+.4f} -> {path} (+ root BENCH_recovery.json)")
    return payload


def run(quick: bool = True):
    """benchmarks/run.py harness contract: (name, us_per_call, derived)."""
    payload = main(["--quick"] if quick else [])
    return [
        ("recovery.mttr", payload["mttr_s"] * 1e6,
         f"{payload['mttr_s']:.2f}s"),
        ("recovery.chaos_overhead", 0.0,
         f"{payload['chaos_overhead_x']:.2f}x"),
        ("recovery.loss_delta", 0.0, f"{payload['loss_delta']:+.4f}"),
    ]


if __name__ == "__main__":
    main()
