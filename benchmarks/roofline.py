"""Roofline analysis (§Roofline): three terms per (arch x shape x mesh).

Reads the dry-run JSONs (experiments/dryrun/*.json) and combines:
  compute term    = analytic HLO flops / (chips x 197 TFLOP/s bf16)
  memory term     = analytic HBM bytes / (chips x 819 GB/s)
  collective term = parsed wire bytes / (chips x 50 GB/s ICI link)

Methodology notes (validated in tests):
  * XLA cost_analysis() counts while-loop bodies once — its raw flops are
    reported for reference but the compute/memory terms use the analytic
    model (repro.analysis.perfmodel), cross-checked against unrolled HLO.
  * Collective bytes come from the compiled HLO with trip-count-aware
    multiplicities and max(result, operand) payloads per op; the wire
    model applies 2x for all-reduce (ring both phases), 1x otherwise,
    with payloads already per-device in partitioned SPMD HLO.
  * MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (inference);
    roofline_fraction = ideal model-flops time / max(term) — what MFU
    would be if the step ran exactly at its binding roofline.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip (v5e-class)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def _analytic(cell: Dict[str, Any]) -> Tuple[float, float, float]:
    """(flops, hbm_bytes, model_flops) global per step for this cell."""
    from repro import configs
    from repro.analysis import perfmodel
    from repro.configs.base import SHAPES_BY_NAME

    cfg = configs.get_config(cell["arch"])
    shape = SHAPES_BY_NAME[cell["shape"]]
    chips = cell["devices"]
    policy = cell.get("policy", {})
    remat = "full" if cell["kind"] == "train" else "none"
    f = perfmodel.cell_flops(cfg, shape, remat=remat)
    b = perfmodel.cell_bytes(cfg, shape, chips=chips, model_shard=16,
                             zero1=policy.get("zero1", True), remat=remat)
    if cell["kind"] == "train":
        return f.train, b.train, f.model_flops_train
    if cell["kind"] == "prefill":
        return f.fwd, b.fwd, f.model_flops_fwd
    t = shape.global_batch * 1
    from repro.models import registry
    n_active = registry.param_count(cfg, active_only=True)
    return f.decode, b.decode, 2.0 * n_active * t


def wire_bytes(coll: Dict[str, Any]) -> float:
    total = 0.0
    for kind, d in coll.get("per_kind", {}).items():
        payload = d.get("wire_bytes", d.get("bytes", 0.0))
        total += WIRE_FACTOR.get(kind, 1.0) * payload
    return total


def analyze_cell(cell: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if cell.get("status") != "ok":
        return None
    chips = cell["devices"]
    flops, hbm, model_flops = _analytic(cell)
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = hbm / (chips * HBM_BW)
    t_coll = wire_bytes(cell["collectives"]) / ICI_BW   # already per-device
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = terms[dominant]
    t_ideal = model_flops / (chips * PEAK_FLOPS)
    mem = cell.get("memory", {})
    per_dev_gb = ((mem.get("argument_bytes") or 0)
                  + (mem.get("temp_bytes") or 0)) / 1e9
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "tag": cell.get("tag", ""), "chips": chips,
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant, "bound_s": t_bound,
        "model_flops": model_flops, "hlo_flops_analytic": flops,
        "hlo_flops_raw_undercounted": cell["cost"]["flops"],
        "useful_flops_ratio": model_flops / max(flops, 1.0),
        "roofline_fraction": t_ideal / max(t_bound, 1e-30),
        "mem_gb_per_device": per_dev_gb,
        "policy": cell.get("policy", {}),
    }


def what_would_help(row: Dict[str, Any]) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("cut wire bytes: bf16 grads / reduce-scatter instead of "
                "all-reduce / fewer per-layer gathers (fuse FSDP prefetch)")
    if d == "memory":
        return ("cut HBM traffic: larger microbatch (amortize param reads), "
                "fuse optimizer, quantize cache/params")
    return ("raise MXU utilization: bigger per-chip tiles, remove remat "
            "recompute, fuse attention (Pallas kernel)")


def load_rows(tag: str = "") -> List[Dict[str, Any]]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        if cell.get("tag", "") != tag:
            continue
        r = analyze_cell(cell)
        if r:
            rows.append(r)
    return rows


def markdown_table(rows: List[Dict[str, Any]], mesh: str = "single") -> str:
    lines = ["| arch | shape | comp s | mem s | coll s | dominant | "
             "roofline frac | useful ratio | GB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_flops_ratio']:.2f} | {r['mem_gb_per_device']:.1f} |")
    return "\n".join(lines)


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    rows = load_rows()
    if not rows:
        return [("roofline.cells", 0.0, "no dryrun results found")]
    from benchmarks import common
    common.save_json("roofline", {"rows": rows})
    out = [("roofline.cells", 0.0, str(len(rows)))]
    worst = sorted((r for r in rows if r["mesh"] == "single"),
                   key=lambda r: r["roofline_fraction"])
    for r in worst[:3]:
        out.append((f"roofline.worst.{r['arch']}.{r['shape']}", 0.0,
                    f"frac={r['roofline_fraction']:.2f},dom={r['dominant']}"))
    coll_bound = [r for r in rows if r["dominant"] == "collective"
                  and r["mesh"] == "single"]
    out.append(("roofline.collective_bound_cells", 0.0, str(len(coll_bound))))
    return out


if __name__ == "__main__":
    rows = load_rows()
    print(markdown_table(rows, "single"))
    print()
    print(markdown_table(rows, "multi"))
