"""Paper Fig. 6: estimated time to converge for each (N, b) split of a
fixed 100-machine budget — the paper's headline trade-off, whose optimum
was N=96, b=4.

time(N) = iters(N) x mean_iteration_time(BackupWorkers(N, 100-N))
with iters(N) = a + c/N (fit from bench_iterations_vs_n when available,
otherwise interpolated from the paper's own Fig. 5 numbers) and iteration
times simulated from the calibrated latency model.
Validated claim: the optimum is interior — a few backups beat both b=0
(straggler-bound) and large b (gradient-variance-bound).
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Tuple

import numpy as np

from benchmarks import common
from repro.core import events, straggler
from repro.core.aggregation import BackupWorkers


def _paper_fit():
    # paper Fig. 5: ~137.5e3 @ 50, ~76.2e3 @ 100 => iters = a + c/N
    c = (137.5e3 - 76.2e3) / (1 / 50 - 1 / 100)
    a = 76.2e3 - c / 100
    return a, c


def _iters_model():
    """iters(N) over N in [50, 100]. Prefer the tiny-LM fit when its
    curvature is strong enough to extrapolate (iters(50)/iters(100) >=
    1.2); otherwise use the paper's own Fig. 5 endpoints — composing OUR
    iteration-time simulation with THEIR iteration counts, which is
    exactly the estimate the paper performs for Fig. 6."""
    path = os.path.join(common.OUT_DIR, "iterations_vs_n.json")
    if os.path.exists(path):
        with open(path) as f:
            fit = json.load(f)
        a, c = fit["fit_a"], fit["fit_c"]
        i50, i100 = a + c / 50, a + c / 100
        if i100 > 0 and i50 / i100 >= 1.2:
            return lambda n: a + c / n, "fitted(tiny-lm)"
    a, c = _paper_fit()
    return lambda n: a + c / n, "paper-fig5-interpolated"


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    total = 100
    ns = list(range(50, 101, 5 if quick else 1))
    iters_fn, iters_src = _iters_model()
    lat = straggler.PaperCalibrated()
    sim_iters = 800 if quick else 4000
    t0 = time.time()
    times = {}
    step_times = {}
    for n in ns:
        st = events.mean_iteration_time(BackupWorkers(n, total - n), lat,
                                        iters=sim_iters, seed=0)
        step_times[n] = st
        times[n] = st * iters_fn(n)
    best_n = min(times, key=times.get)
    b = total - best_n
    t_full = times[100]                      # b=0: wait for everyone
    t_best = times[best_n]
    rows = [
        ("time_to_converge.best_split", (time.time() - t0) * 1e6 / len(ns),
         f"N={best_n},b={b}"),
        ("time_to_converge.speedup_vs_b0", 0.0,
         f"{t_full / t_best:.2f}x"),
        ("time_to_converge.interior_optimum", 0.0,
         str(50 < best_n < 100)),
    ]
    common.save_json("time_to_converge", {
        "total_machines": total, "iters_source": iters_src,
        "mean_step_time": step_times, "est_time": times,
        "best": {"N": best_n, "b": b},
        "paper_claim": "optimum N=96,b=4 of 100 (interior)",
    })
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
