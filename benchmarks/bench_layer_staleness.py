"""Paper Table 1 / Fig. 1: gradient staleness DEPENDS ON LAYER DEPTH.

Parameters are read bottom-up during forward prop and gradients are sent
top-down during backprop, so a lower layer's read->update window is wider:
the paper measured mean staleness ~14.5 at the top layer vs ~39.0 at the
bottom (40 async workers).

Event simulation: each worker's iteration occupies [t0, t1]; layer l (of
L) is read at t0 + (l/L) * f * (t1-t0) and its gradient lands at
t1 - (l/L) * b * (t1-t0) (f, b = forward/backward time fractions). The
staleness of layer l's gradient = number of PS updates in its window.
Validated claim: staleness decreases monotonically with depth, bottom ~2x
top, mean ~ #workers — the paper's Table 1 shape.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks import common
from repro.core.straggler import LogNormal


def simulate_layer_staleness(num_workers: int = 40, num_layers: int = 19,
                             iters: int = 400, fwd_frac: float = 0.33,
                             bwd_frac: float = 0.31, seed: int = 0):
    """Returns mean staleness per layer index (0 = bottom, L-1 = top)."""
    rng = np.random.RandomState(seed)
    lat = LogNormal(median=1.5, sigma=0.2)
    # worker w iteration k occupies [start[w,k], end[w,k]]
    durations = lat.sample(rng, (num_workers, iters))
    ends = np.cumsum(durations, axis=1)
    starts = ends - durations
    # global update timeline: one PS update at each gradient arrival (the
    # full gradient is applied when the last (bottom) layer grad lands)
    update_times = np.sort(ends.reshape(-1))

    frac = np.arange(num_layers) / max(num_layers - 1, 1)   # 0=bottom? see below
    # layer l (0=bottom): read early in fwd, sent late in bwd
    # read offset fraction rises with height; send offset fraction falls
    stal = np.zeros(num_layers)
    for w in range(num_workers):
        for k in range(1, iters):                     # skip warmup iteration
            t0, t1 = starts[w, k], ends[w, k]
            dur = t1 - t0
            read_t = t0 + frac * fwd_frac * dur       # top read latest
            send_t = t1 - frac * bwd_frac * dur       # top sent earliest
            lo = np.searchsorted(update_times, read_t)
            hi = np.searchsorted(update_times, send_t)
            stal += hi - lo
    stal /= num_workers * (iters - 1)
    return stal            # index 0 = bottom layer, L-1 = top layer


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    iters = 200 if quick else 1000
    t0 = time.time()
    stal = simulate_layer_staleness(num_workers=40, num_layers=19,
                                    iters=iters)
    us = (time.time() - t0) * 1e6 / iters
    bottom, top = float(stal[0]), float(stal[-1])
    monotone = bool(np.all(np.diff(stal) <= 1e-9))
    common.save_json("layer_staleness", {
        "per_layer_mean": stal.tolist(),
        "bottom": bottom, "top": top, "ratio": bottom / max(top, 1e-9),
        "monotone_decreasing_with_height": monotone,
        "paper_claim": "Table 1 (40 workers, 19-layer Inception): layer 0"
                       " mean ~39.0 vs layer 18 mean ~14.5 (~2.7x)",
    })
    return [
        ("layer_staleness.sim_iter", us, f"workers=40,layers=19"),
        ("layer_staleness.bottom_layer", 0.0, f"{bottom:.1f}"),
        ("layer_staleness.top_layer", 0.0, f"{top:.1f}"),
        ("layer_staleness.bottom_over_top", 0.0, f"{bottom / max(top, 1e-9):.2f}x"),
        ("layer_staleness.monotone_in_depth", 0.0, str(monotone)),
    ]


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
