"""Replica-router bench: hedging tail-cut, chaos accounting, SLO goodput.

Everything runs on the router's deterministic virtual clock (1 decode
step = 1 unit), so the numbers are machine-independent and every arm is
bit-replayable. Five arms over one shared engine (replicas are
StepSessions of the same build):

* ``baseline`` — R healthy replicas, moderate load.
* ``chaos_unhedged`` / ``chaos_hedged`` — one replica is a 20x
  straggler for the whole run; the hedged arm re-dispatches requests
  whose age crosses max(windowed p95, floor) to a second replica and
  takes the first completion. Headline: ``hedged_vs_unhedged_p99``
  (the acceptance bar is >= 2x).
* ``chaos_mix`` — crash + restart + preemption + slowdown with hedging
  on, run twice: asserts ``chaos_lost_requests == 0``, byte-identical
  replay, and greedy token parity with a single-engine reference.
* ``slo_shed`` — sustained overload on one replica with and without the
  windowed-p99 admission gate: ``goodput_shed`` vs ``goodput_unshed``
  and the served-tail p99 each way; plus a burst-then-trickle trace
  showing the controller re-opening (``slo_reentered``).

Writes experiments/bench/BENCH_router.json + the repo-root headline
mirror (schema: docs/perf.md).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import write_bench

REPLICAS = 3
STRAGGLER = "slowdown@0:r0:x20:d1000"
MIX = ("slowdown@0:r0:x8:d50,crash@10:r2,restart@30:r2,"
       "preempt@40:r1:d8")


def _arm(m, **extra):
    out = {"completed": m["completed"], "rejected": m["rejected"],
           "lost_requests": m["lost_requests"], "goodput": m["goodput"],
           "p50_latency": m["p50_latency"], "p99_latency": m["p99_latency"],
           "hedges": m["hedges"], "hedge_wins": m["hedge_wins"],
           "drained": m["drained"], "crashes": m["crashes"],
           "preempts": m["preempts"], "restarts": m["restarts"],
           "shed": m["shed"]}
    out.update(extra)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="short traces (CI canary settings)")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args(argv)
    requests = args.requests or (32 if args.quick else 64)

    import jax
    from repro import configs
    from repro.models import get_model
    from repro.serve import (ReplicaRouter, RouterConfig, SLOConfig,
                             ServeEngine, TraceConfig, make_trace)

    cfg = configs.get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, num_slots=2, page_size=4,
                         max_prompt_len=12, max_new_cap=8, clock="virtual")

    def trace(n=requests, rate=2.0, seed=0, min_new=4, max_new=8):
        return make_trace(TraceConfig(
            num_requests=n, rate=rate, prompt_len_min=2, prompt_len_max=12,
            max_new_min=min_new, max_new_max=max_new, vocab=cfg.vocab_size,
            seed=seed))

    def route(tr, rc, slo=None):
        return ReplicaRouter(engine, rc, slo=slo).run(tr)

    arms = {}
    tr = trace()
    ref_tokens = engine.run(tr).tokens_by_rid()

    # -- baseline -------------------------------------------------------------
    base = route(tr, RouterConfig(num_replicas=REPLICAS))
    arms["baseline"] = _arm(base.metrics)
    print(f"baseline    p99 {base.metrics['p99_latency']:7.1f} "
          f"goodput {base.metrics['goodput']:.3f}")

    # -- straggler replica: hedged vs unhedged --------------------------------
    # offered load below the *healthy* capacity (2 of 3 replicas), so the
    # tail is pure straggler effect, not queueing saturation — hedging
    # fixes stragglers, it cannot manufacture capacity
    strag_tr = trace(rate=0.5, seed=1)
    unhedged = route(strag_tr, RouterConfig(num_replicas=REPLICAS,
                                            faults=STRAGGLER))
    hedged = route(strag_tr, RouterConfig(num_replicas=REPLICAS,
                                          faults=STRAGGLER, hedge_after=6.0))
    ratio = unhedged.metrics["p99_latency"] / \
        max(hedged.metrics["p99_latency"], 1e-9)
    arms["chaos_unhedged"] = _arm(unhedged.metrics)
    arms["chaos_hedged"] = _arm(hedged.metrics)
    print(f"straggler   p99 {unhedged.metrics['p99_latency']:7.1f} -> "
          f"{hedged.metrics['p99_latency']:7.1f} hedged "
          f"({ratio:.2f}x, {hedged.metrics['hedges']} hedges)")

    # -- chaos mix: zero lost, bit-identical replay, token parity -------------
    mix_cfg = lambda: RouterConfig(  # noqa: E731
        num_replicas=REPLICAS, faults=MIX, hedge_after=6.0)
    mix_a, mix_b = route(tr, mix_cfg()), route(tr, mix_cfg())
    replay_identical = (mix_a.metrics == mix_b.metrics
                        and mix_a.events == mix_b.events
                        and mix_a.health == mix_b.health
                        and mix_a.tokens_by_rid() == mix_b.tokens_by_rid())
    parity = all(ref_tokens[c.rid] == c.tokens for c in mix_a.completed)
    arms["chaos_mix"] = _arm(mix_a.metrics,
                             replay_identical=replay_identical,
                             token_parity=parity)
    print(f"chaos mix   lost {mix_a.metrics['lost_requests']} "
          f"replay_identical {replay_identical} token_parity {parity}")

    # -- SLO admission: goodput under sustained overload ----------------------
    over = trace(rate=1.0, seed=3)
    unshed = route(over, RouterConfig(num_replicas=1))
    shed = route(over, RouterConfig(num_replicas=1),
                 slo=SLOConfig(target_p99=10.0, window=16, min_samples=4))
    arms["slo_unshed"] = _arm(unshed.metrics)
    arms["slo_shed"] = _arm(shed.metrics,
                            slo_trips=shed.metrics["slo_trips"])
    shed_fraction = shed.metrics["shed"] / max(shed.metrics["total"], 1)
    print(f"slo shed    p99 {unshed.metrics['p99_latency']:7.1f} -> "
          f"{shed.metrics['p99_latency']:7.1f} shedding "
          f"{shed_fraction:.2f} of load")

    # -- SLO hysteresis: burst, then the gate must re-open --------------------
    # sizes pinned: the tail must hold enough probe completions to flush
    # the estimator window (8) or the gate can't demonstrably re-open
    burst = trace(n=24, rate=4.0, seed=3)
    tail = trace(n=20, rate=0.15, seed=4, min_new=2, max_new=4)
    t0 = burst[-1].arrival + 12.0
    btt = list(burst) + [
        dataclasses.replace(r, rid=1000 + r.rid, arrival=t0 + r.arrival)
        for r in tail]
    recov = route(btt, RouterConfig(num_replicas=1),
                  slo=SLOConfig(target_p99=15.0, window=8, min_samples=4,
                                quantile=90.0, probe_every=2))
    arms["slo_recover"] = _arm(recov.metrics,
                               slo_trips=recov.metrics["slo_trips"],
                               slo_reentered=recov.metrics["slo_reentered"])
    print(f"slo recover trips {recov.metrics['slo_trips']} "
          f"reentered {recov.metrics['slo_reentered']}")

    chaos_lost = (mix_a.metrics["lost_requests"]
                  + hedged.metrics["lost_requests"]
                  + unhedged.metrics["lost_requests"])
    payload = {
        "bench": "router",
        "model": "qwen3-0.6b smoke",
        "replicas": REPLICAS,
        "slots_per_replica": engine.pool_cfg.num_slots,
        "requests": requests,
        "arms": arms,
        "hedged_vs_unhedged_p99": ratio,
        "chaos_lost_requests": chaos_lost,
        "replay_identical": replay_identical,
        "token_parity": parity,
        "goodput_shed": shed.metrics["goodput"],
        "goodput_unshed": unshed.metrics["goodput"],
        "shed_fraction": shed_fraction,
        "slo_reentered": recov.metrics["slo_reentered"],
    }
    mirror = {k: payload[k] for k in (
        "bench", "replicas", "hedged_vs_unhedged_p99", "chaos_lost_requests",
        "replay_identical", "token_parity", "goodput_shed", "shed_fraction",
        "slo_reentered")}
    path = write_bench("BENCH_router", payload, mirror=mirror)
    print(f"hedged vs unhedged p99: {ratio:.2f}x, chaos lost "
          f"{chaos_lost} -> {path} (+ root BENCH_router.json)")
    return payload


def run(quick: bool = True):
    """benchmarks/run.py harness contract: (name, us_per_call, derived)."""
    payload = main(["--quick"] if quick else [])
    return [
        ("router.hedged_vs_unhedged_p99", 0.0,
         f"{payload['hedged_vs_unhedged_p99']:.2f}x"),
        ("router.chaos_lost_requests", 0.0,
         str(payload["chaos_lost_requests"])),
        ("router.goodput_shed", 0.0, f"{payload['goodput_shed']:.3f}/u"),
    ]


if __name__ == "__main__":
    main()
