"""Host-CPU step-time microbenchmark: wall time per jitted train step for
every assigned architecture's smoke config (the ``name,us_per_call``
contract; TPU numbers come from the dry-run roofline, not wall time)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import configs
from repro.models import get_model
from repro.optim import optimizers as opt_lib
from repro.optim import schedules
from repro.train.train_step import build_train_step


def _bench_arch(arch: str, iters: int) -> float:
    cfg = configs.get_smoke_config(arch)
    model = get_model(cfg)
    opt = opt_lib.sgd(schedules.constant(0.01))
    step = jax.jit(build_train_step(model, opt, num_workers=4, n_aggregate=3),
                   donate_argnums=(0, 1))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    b, s = 8, 32
    batch = {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.zeros((b, cfg.num_prefix_embeds,
                                            cfg.d_model))
    if cfg.family == "audio":
        batch["encoder_frames"] = jnp.zeros((b, cfg.encoder_seq_len,
                                             cfg.d_model))
    mask = jnp.ones((4,), bool)
    sc = jnp.asarray(0, jnp.int32)
    params, opt_state, _, _ = step(params, opt_state, None, sc, batch, mask)
    jax.block_until_ready(params)
    t0 = time.time()
    for i in range(iters):
        params, opt_state, _, m = step(params, opt_state, None, sc, batch, mask)
    jax.block_until_ready(params)
    return (time.time() - t0) * 1e6 / iters


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    iters = 3 if quick else 20
    rows = []
    for arch in configs.list_archs():
        us = _bench_arch(arch, iters)
        rows.append((f"step_time.{arch}", us, "smoke-config CPU train step"))
    common.save_json("step_time", {r[0]: r[1] for r in rows})
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
