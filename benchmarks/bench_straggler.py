"""Paper Figs. 3 & 4: straggler order statistics on N=100 workers.

Fig. 3: CDF of time to collect the k-th gradient (k = 1, 50, 90, 97..100).
Fig. 4: mean/median time to collect k gradients.
Validated claims: flat middle (most mean times 1.4-1.8s), exponential tail
for the last few gradients, max observed latency <= 310s.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks import common
from repro.core import straggler


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    iters = 2000 if quick else 20000
    t0 = time.time()
    rng = np.random.RandomState(0)
    lat = straggler.PaperCalibrated().sample(rng, (iters, 100))
    mean_k, med_k = straggler.mean_median_time_to_k(lat)
    grid = np.linspace(0, 6.0, 61)
    cdfs = {k: straggler.cdf_of_time_to_k(lat, k, grid).tolist()
            for k in (1, 50, 90, 97, 98, 99, 100)}
    elapsed_us = (time.time() - t0) * 1e6 / iters

    frac_98_under_2s = float(straggler.cdf_of_time_to_k(lat, 98,
                                                        np.array([2.0]))[0])
    frac_100_under_2s = float(straggler.cdf_of_time_to_k(lat, 100,
                                                         np.array([2.0]))[0])
    common.save_json("straggler", {
        "iters": iters,
        "grid": grid.tolist(),
        "cdf": cdfs,
        "mean_time_to_k": mean_k.tolist(),
        "median_time_to_k": med_k.tolist(),
        "paper_claims": {
            "frac_98th_under_2s": frac_98_under_2s,     # paper: ~0.8
            "frac_100th_under_2s": frac_100_under_2s,   # paper: ~0.3
            "mean_k50": float(mean_k[49]),              # paper: 1.4-1.8
            "mean_k100": float(mean_k[99]),             # paper: tail explodes
            "max_latency": float(lat.max()),            # paper: 310s
        },
    })
    return [
        ("straggler.sim_iter", elapsed_us, f"mean_k50={mean_k[49]:.2f}s"),
        ("straggler.k98_cdf2s", 0.0, f"{frac_98_under_2s:.2f}"),
        ("straggler.k100_cdf2s", 0.0, f"{frac_100_under_2s:.2f}"),
        ("straggler.mean_k100", 0.0, f"{mean_k[99]:.1f}s"),
    ]


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
