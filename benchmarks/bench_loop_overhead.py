"""Host/dispatch overhead of the training loop: legacy vs fused chunks.

Measures wall-clock steps/s of the qwen3-0.6b smoke config (CPU-sized) for
chunk_size in {1, 8, 32}. chunk_size=1 is the legacy per-step path — one
jit dispatch, one batch+mask transfer, and one metrics float() sync per
step; larger chunks fuse K iterations into a single lax.scan dispatch with
one stacked transfer and one sync per chunk. On smoke-scale models the
per-step Python/dispatch overhead dominates, so this ratio tracks exactly
the overhead the chunked loop retires (docs/perf.md).

Writes experiments/bench/BENCH_loop.json. With --device also measures the
fully device-resident 'device' straggler backend at chunk_size=32.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np

from common import write_bench

CHUNK_SIZES = (1, 8, 32)


def build_trainer(chunk_size: int, backend: str = "host"):
    from repro import configs
    from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                    OptimizerConfig, ShapeConfig, TrainConfig)
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    # smoke model, small shape: per-step device compute is a few ms, so the
    # measurement isolates the loop's host/dispatch overhead (the thing this
    # benchmark exists to track) rather than model FLOPs
    cfg = TrainConfig(
        model=configs.get_smoke_config("qwen3-0.6b"),
        shape=ShapeConfig("bench", 4, 6, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=4,
                                      backup_workers=2),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.02,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.999),
        checkpoint=CheckpointConfig(every_steps=0),
        # per-step logging, as in real training: the legacy path pays a
        # metrics float() sync every step (part of the overhead the fused
        # loop retires — it reads the whole chunk's metrics back in one go)
        log_every=1,
        chunk_size=chunk_size, straggler_backend=backend)
    tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    tr.init_state()
    return tr


def measure_all(configs, steps: int, reps: int = 3):
    """Build+compile every config first, then interleave the timed reps
    (cfg0, cfg1, ..., cfg0, cfg1, ...) so CPU thermal drift doesn't
    systematically penalize whichever config is measured last."""
    trainers = []
    for chunk_size, backend in configs:
        tr = build_trainer(chunk_size, backend)
        tr.run(max(chunk_size, 8))                 # compile + warm caches
        trainers.append(tr)
    best = [None] * len(configs)
    for _ in range(reps):
        for i, tr in enumerate(trainers):
            t0 = time.perf_counter()
            tr.run(steps)
            dt = time.perf_counter() - t0
            best[i] = dt if best[i] is None or dt < best[i] else best[i]
    return [{"chunk_size": c, "backend": b, "steps": steps,
             "wall_s": w, "steps_per_s": steps / w}
            for (c, b), w in zip(configs, best)]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed steps (CI)")
    ap.add_argument("--host-only", action="store_true",
                    help="skip the device-resident backend measurements")
    args = ap.parse_args(argv)

    steps = 64 if args.quick else 192
    # chunk_size=1 is the legacy per-step loop; chunked rows are measured in
    # both modes: 'host' (bit-exact numpy straggler streams) and 'device'
    # (the fully device-resident tentpole — batch gen + arrival sampling +
    # mask selection inside the scan). The headline speedup compares the
    # full fused loop against the legacy path.
    configs = [(c, "host") for c in CHUNK_SIZES]
    if not args.host_only:
        configs += [(c, "device") for c in CHUNK_SIZES if c > 1]
    results = measure_all(configs, steps)

    legacy = next(r for r in results
                  if r["chunk_size"] == 1 and r["backend"] == "host")

    def rate(chunk, backend=None):
        rates = [r["steps_per_s"] for r in results if r["chunk_size"] == chunk
                 and (backend is None or r["backend"] == backend)]
        return max(rates) if rates else None

    def speedup(chunk, backend=None):
        r = rate(chunk, backend)
        return r / legacy["steps_per_s"] if r else None

    payload = {
        "bench": "loop_overhead",
        "model": "qwen3-0.6b smoke",
        "steps": steps,
        "results": results,
        # headline: best fused configuration vs the legacy loop
        "speedup_8_vs_1": speedup(8),
        "speedup_32_vs_1": speedup(32),
        # per-backend canaries so a regression in one mode can't hide
        # behind the other being faster
        "speedup_32_host_vs_1": speedup(32, "host"),
        "speedup_32_device_vs_1": speedup(32, "device"),
    }
    # root mirror: the headline speedups only (the perf-trajectory file)
    path = write_bench("BENCH_loop", payload,
                       mirror={k: payload[k] for k in
                               ("bench", "speedup_8_vs_1", "speedup_32_vs_1",
                                "speedup_32_host_vs_1",
                                "speedup_32_device_vs_1")})
    for r in results:
        print(f"chunk_size={r['chunk_size']:>3} backend={r['backend']:<6} "
              f"{r['steps_per_s']:8.1f} steps/s")
    print(f"speedup 32 vs 1: {payload['speedup_32_vs_1']:.2f}x  -> {path}")
    return payload


if __name__ == "__main__":
    main()
