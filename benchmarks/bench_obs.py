"""Telemetry bench: tracer overhead + perfmodel prediction vs measured.

Two CI-tracked numbers (docs/perf.md BENCH_obs schema):

* ``tracer_overhead_pct`` — the disabled-tracing path. Every
  instrumentation site in the chunked trainer costs one shared no-op
  context manager per hook when no tracer is passed; this measures that
  hook cost directly (a tight loop over the NULL tracer) and expresses
  it against the measured per-chunk wall time. The acceptance bar (and
  tests/test_obs.py) holds it under 2%.
* ``predicted_vs_measured_err`` — closes ROADMAP's "perfmodel
  prediction vs measured as a CI number". The analytic FLOP model
  (``analysis/perfmodel.cell_flops``) is calibrated on ONE shape
  (achieved FLOP/s = predicted train FLOPs / fenced measured step
  time), then predicts the step time of the remaining shapes; the
  reported number is the mean relative error of those predictions
  against traced (fenced) measurements.

Also reports ``traced_overhead_pct`` — the cost of *enabled* tracing
(spans + chunk-edge fences) against the fence-only baseline.

Writes experiments/bench/BENCH_obs.json + the repo-root headline mirror.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import tiny_lm_config, write_bench

# hooks the chunked trainer's hot loop runs per chunk on the disabled
# path: train/chunk + train/data_wait + train/device_wait spans and two
# perf_counter-gated _now() calls (counted generously as a hook each)
HOOKS_PER_CHUNK = 5


def _build_trainer(seq: int, batch: int, tmpdir: str, tracer=None,
                   metrics=None):
    from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                    OptimizerConfig, ShapeConfig,
                                    TrainConfig)
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    cfg = TrainConfig(
        model=tiny_lm_config(),
        shape=ShapeConfig("bench_obs", seq, batch, "train"),
        aggregation=AggregationConfig(strategy="full_sync", num_workers=4),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False),
        checkpoint=CheckpointConfig(directory=tmpdir, every_steps=0),
        log_every=1000, chunk_size=8, straggler_backend="host")
    tr = Trainer(cfg, latency=Uniform(1.0, 2.0), tracer=tracer,
                 metrics=metrics)
    tr.init_state()
    return tr


def _fenced_step_s(tr, warmup_steps: int, steps: int) -> float:
    """Mean fenced device-dispatch seconds per step (data time excluded:
    the FLOP model predicts compute, not host staging)."""
    tr.run(warmup_steps)
    d0, s0 = tr._phase["dispatch_s"], tr.step
    tr.run(steps)
    return (tr._phase["dispatch_s"] - d0) / (tr.step - s0)


def _null_hook_cost_s() -> float:
    """Per-hook cost of the disabled path: one shared no-op span."""
    from repro.obs.trace import NULL
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with NULL.span("train/chunk"):
            pass
    return (time.perf_counter() - t0) / n


def main(quick: bool = True) -> dict:
    import tempfile

    from repro.analysis.perfmodel import cell_flops
    from repro.configs.base import ShapeConfig
    from repro.obs import MetricsRegistry, Tracer

    warmup, steps = (8, 24) if quick else (16, 64)
    # calibration shape first; the rest are predicted from its FLOP/s
    shapes = [(32, 16), (64, 16), (32, 32)]
    model_cfg = tiny_lm_config()

    cells = []
    for seq, batch in shapes:
        with tempfile.TemporaryDirectory() as tmp:
            tr = _build_trainer(seq, batch, tmp, metrics=MetricsRegistry())
            step_s = _fenced_step_s(tr, warmup, steps)
        flops = cell_flops(model_cfg,
                           ShapeConfig("bench_obs", seq, batch, "train"))
        cells.append({"seq_len": seq, "global_batch": batch,
                      "measured_step_s": step_s,
                      "train_flops": flops.train})
        print(f"[obs] shape seq={seq} batch={batch}: "
              f"{step_s * 1e3:.2f} ms/step "
              f"({flops.train / step_s / 1e9:.2f} GFLOP/s)")

    calib = cells[0]
    flops_per_s = calib["train_flops"] / calib["measured_step_s"]
    errs = []
    for c in cells:
        c["predicted_step_s"] = c["train_flops"] / flops_per_s
        c["rel_err"] = (abs(c["predicted_step_s"] - c["measured_step_s"])
                        / c["measured_step_s"])
        if c is not calib:
            errs.append(c["rel_err"])
    predicted_vs_measured_err = sum(errs) / len(errs)

    # disabled-path overhead: measured hook cost vs the measured chunk
    hook_s = _null_hook_cost_s()
    chunk_s = calib["measured_step_s"] * 8          # chunk_size=8
    tracer_overhead_pct = 100.0 * HOOKS_PER_CHUNK * hook_s / chunk_s

    # enabled-path overhead: spans + export bookkeeping vs fence-only
    with tempfile.TemporaryDirectory() as tmp:
        tr = _build_trainer(32, 16, tmp, tracer=Tracer(),
                            metrics=MetricsRegistry())
        traced_step_s = _fenced_step_s(tr, warmup, steps)
    traced_overhead_pct = 100.0 * max(
        traced_step_s - calib["measured_step_s"], 0.0) \
        / calib["measured_step_s"]

    payload = {
        "tracer_overhead_pct": tracer_overhead_pct,
        "traced_overhead_pct": traced_overhead_pct,
        "predicted_vs_measured_err": predicted_vs_measured_err,
        "null_hook_cost_us": hook_s * 1e6,
        "calibration_flops_per_s": flops_per_s,
        "cells": cells,
        "quick": quick,
    }
    mirror = {
        "tracer_overhead_pct": tracer_overhead_pct,
        "predicted_vs_measured_err": predicted_vs_measured_err,
    }
    path = write_bench("BENCH_obs", payload, mirror)
    print(f"[obs] tracer_overhead {tracer_overhead_pct:.4f}% "
          f"traced_overhead {traced_overhead_pct:.1f}% "
          f"predicted_vs_measured_err {predicted_vs_measured_err:.3f}")
    print(f"-> {path} (+ root BENCH_obs.json)")
    return payload


def run(quick: bool = True):
    """benchmarks/run.py harness contract: (name, us_per_call, derived)."""
    payload = main(quick=quick)
    rows = [("obs.tracer_overhead", 0.0,
             f"{payload['tracer_overhead_pct']:.4f}%"),
            ("obs.traced_overhead", 0.0,
             f"{payload['traced_overhead_pct']:.1f}%"),
            ("obs.predicted_vs_measured_err", 0.0,
             f"{payload['predicted_vs_measured_err']:.3f}")]
    rows += [(f"obs.step_s{c['seq_len']}x{c['global_batch']}",
              c["measured_step_s"] * 1e6,
              f"rel_err={c['rel_err']:.3f}") for c in payload["cells"]]
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick or os.environ.get(
        "REPRO_BENCH_FULL", "0") not in ("1", "true"))
