"""Paper Fig. 5: iterations to converge vs number of aggregated workers N.

Sync-Opt with effective batch N*B needs fewer iterations as N grows (the
paper: 137.5e3 @ N=50 -> 76.2e3 @ N=100, near-halving). Reproduced on the
tiny LM: steps to reach a target held-out loss for N in a 4x range, fitted
to iters(N) ~ a + c/N (used by bench_time_to_converge for Fig. 6).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from benchmarks import common
from repro.core import sync_backup


def steps_to_target(n_workers: int, target: float, max_steps: int,
                    batch_per_worker: int = 2, lr: float = 0.15,
                    seed: int = 0) -> int:
    """Noise-limited regime: tiny per-worker batches so the gradient
    variance (∝ 1/N) is what gates progress — the paper's Fig. 5 effect."""
    model, params, grad_fn, batch_fn, eval_fn = common.tiny_lm_problem(
        batch=batch_per_worker, workers=n_workers, seed=seed, seq=16)
    update = common.sgd_update_fn(lr)

    @jax.jit
    def sync_step(params, batches):
        def loss(p):
            losses = []
            for b in batches:
                lt, aux = model.per_token_loss(p, b)
                losses.append(lt.mean() + aux)
            return sum(losses) / len(losses)
        l, g = jax.value_and_grad(loss)(params)
        return l, g

    for step in range(max_steps):
        batches = [batch_fn(w, step) for w in range(n_workers)]
        _, grads = sync_step(params, batches)
        params, _ = update(params, None, grads, step)
        if step % 5 == 0 and eval_fn(params) <= target:
            return step
    return max_steps


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    ns = [1, 2, 4, 8] if quick else [1, 2, 4, 8, 16]
    target = 2.45          # close to the noise floor => variance-limited
    max_steps = 600 if quick else 1500
    rows = []
    iters = {}
    for n in ns:
        t0 = time.time()
        s = steps_to_target(n, target, max_steps)
        iters[n] = s
        rows.append((f"iters_vs_n.N{n}", (time.time() - t0) * 1e6 / max(s, 1),
                     f"iters={s}"))
    # fit iters(N) = a + c/N  (paper's shape: diminishing returns in N)
    a_ns = np.array(list(iters))
    ys = np.array([iters[n] for n in a_ns], float)
    x = np.stack([np.ones_like(a_ns, float), 1.0 / a_ns], 1)
    coef, *_ = np.linalg.lstsq(x, ys, rcond=None)
    halving = iters[ns[0]] / max(iters[ns[-1]], 1)
    rows.append(("iters_vs_n.range_ratio", 0.0,
                 f"{halving:.2f}x fewer iters at {ns[-1] // ns[0]}x workers"))
    common.save_json("iterations_vs_n", {
        "target_loss": target, "iters": iters,
        "fit_a": float(coef[0]), "fit_c": float(coef[1]),
        "paper_claim": "iters nearly halve when N doubles (137.5e3@50 ->"
                       " 76.2e3@100)",
    })
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
