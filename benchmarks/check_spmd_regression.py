"""SPMD perf-regression guard: fresh BENCH_spmd.json vs a baseline.

CI snapshots the committed ``experiments/bench/BENCH_spmd.json`` before
regenerating it, then runs this check (see .github/workflows/ci.yml, the
``spmd`` job): every ``spmd_vs_sim_*`` overhead ratio present in BOTH
payloads must not drop more than ``--threshold`` (default 20%) below
its baseline — a drop means the mesh engine got structurally slower
relative to the simulated backend, on whatever host CI happens to be
(the ratio is dimensionless, so it transfers across machines in a way
raw steps/s never could). The ``spmd_bytes_per_step_*`` axis is guarded
in the opposite direction: collective wire bytes are DETERMINISTIC
(parsed from HLO, not timed), so growing them past the threshold means
the fused reduce-then-psum lost its fusion.

Exit status 1 on any regression, with a per-cell report either way.

Usage:
    python benchmarks/check_spmd_regression.py BASELINE.json FRESH.json \
        [--threshold 0.2]
"""
from __future__ import annotations

import argparse
import json
import sys

RATIO_PREFIX = "spmd_vs_sim_"
BYTES_PREFIX = "spmd_bytes_per_step_"


def compare(baseline: dict, fresh: dict, threshold: float) -> list:
    """Regression records: (key, base, new, relative_change).

    Ratios regress by DROPPING, bytes regress by GROWING; keys present
    in only one payload are reported as informational skips by main()
    but never fail (the schema is allowed to gain cells).
    """
    bad = []
    for key, base in baseline.items():
        if key not in fresh or not isinstance(base, (int, float)):
            continue
        new = fresh[key]
        if key.startswith(RATIO_PREFIX) and base > 0:
            change = (new - base) / base
            if change < -threshold:
                bad.append((key, base, new, change))
        elif key.startswith(BYTES_PREFIX) and base > 0:
            change = (new - base) / base
            if change > threshold:
                bad.append((key, base, new, change))
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_spmd.json snapshot")
    ap.add_argument("fresh", help="freshly generated BENCH_spmd.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="max tolerated relative regression (default 0.2)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    tracked = [k for k in baseline
               if k.startswith((RATIO_PREFIX, BYTES_PREFIX))
               and isinstance(baseline[k], (int, float))]
    if not tracked:
        print("check_spmd_regression: baseline has no tracked keys "
              "(schema too old?) — nothing to guard")
        return 0
    for key in sorted(tracked):
        if key not in fresh:
            print(f"  {key}: only in baseline — skipped")
            continue
        base, new = baseline[key], fresh[key]
        change = (new - base) / base if base else 0.0
        print(f"  {key}: {base:.4g} -> {new:.4g} ({change:+.1%})")

    bad = compare(baseline, fresh, args.threshold)
    if bad:
        print(f"\nREGRESSION (> {args.threshold:.0%}):")
        for key, base, new, change in bad:
            kind = "ratio dropped" if key.startswith(RATIO_PREFIX) \
                else "bytes grew"
            print(f"  {key}: {kind} {base:.4g} -> {new:.4g} ({change:+.1%})")
        return 1
    print(f"\nOK: no tracked key regressed past {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
