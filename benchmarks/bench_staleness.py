"""Paper Fig. 2 / §2.1: simulated gradient staleness degrades the optimum.

The paper trains a 4-layer weight-normalized CNN on MNIST with old
gradients (staleness 0..50), using a staleness ramp over the first epochs.
We reproduce on the synthetic MNIST-like set (CPU scale): test error as a
function of average staleness must increase monotonically, with instability
beyond staleness ~15 without the ramp.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import async_sim
from repro.data import mnist_like
from repro.models import mnist_cnn
from repro.optim import schedules


def _error(model, params, test) -> float:
    logits = model.forward(params, jnp.asarray(test["images"]))
    pred = np.asarray(jnp.argmax(logits, -1))
    return float((pred != test["labels"]).mean())


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    staleness_values = [0, 5, 10, 15] if quick else [0, 5, 10, 20, 35, 50]
    steps = 450 if quick else 1500
    batch = 64
    data_cfg = mnist_like.MnistLikeConfig(num_train=4096, num_test=1024)
    train, test = mnist_like.make_dataset(data_cfg)
    model = mnist_cnn.make(widths=(16, 16, 32, 32))

    # paper §2.1: lower lr needed once staleness >= 20 to avoid blowups;
    # we use the stable-for-all setting so the DEGRADATION (not
    # divergence) is what's measured
    sched = schedules.linear_anneal(0.03, steps, int(steps * 0.6))

    @jax.jit
    def grad_fn(params, batch_):
        def loss(p):
            return model.per_example_loss(p, batch_).mean()
        return jax.value_and_grad(loss)(params)

    def update_fn(params, opt_state, grads, step):
        lr = sched(jnp.asarray(step))
        new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return new, opt_state

    def batch_fn(step):
        rng = np.random.RandomState(1000 + step)
        idx = rng.randint(0, data_cfg.num_train, size=batch)
        return {"images": jnp.asarray(train["images"][idx]),
                "labels": jnp.asarray(train["labels"][idx])}

    rows: List[Tuple[str, float, str]] = []
    errors = {}
    t_all = time.time()
    for tau in staleness_values:
        params0 = model.init(jax.random.PRNGKey(0))
        t0 = time.time()
        # paper evaluates on the EMA; alpha scaled to the run length
        # (0.9999 needs ~25 epochs; 0.99 converges within our budget)
        res = async_sim.simulate_staleness(
            grad_fn, update_fn, params0, batch_fn, num_updates=steps,
            staleness=tau, ramp_steps=max(1, steps // 5),
            ema_decay=0.99)
        err = _error(model, res.ema, test)
        errors[tau] = err
        us = (time.time() - t0) * 1e6 / steps
        rows.append((f"staleness.tau{tau}", us, f"test_err={err:.4f}"))

    monotone = all(errors[a] <= errors[b] + 0.02
                   for a, b in zip(staleness_values, staleness_values[1:]))
    rows.append(("staleness.monotone_degradation", 0.0, str(monotone)))
    common.save_json("staleness", {
        "staleness": staleness_values, "test_error": errors,
        "steps": steps, "monotone": monotone,
        "paper_claim": "0.36% err at tau=0 -> 0.79% at tau=50 (scale-shifted"
                       " here: synthetic data, smaller CNN, fewer steps)",
        "wall_s": time.time() - t_all,
    })
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
