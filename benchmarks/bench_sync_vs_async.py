"""Paper Figs. 8/9 (headline result): Sync-Opt with backup workers
converges FASTER (simulated wall time) and to a BETTER optimum than
Async-Opt at matched worker counts; plain Sync (b=0) is slowed by
stragglers.

Setup: tiny LM, N+b machines under the calibrated latency model. Every
variant routes through the single ``run_experiment(cfg)`` entry point —
only ``AggregationConfig.strategy`` changes between regimes:
  * sync_backup: first N of N+b aggregated (Alg. 3/4)
  * sync_full:   all N+b aggregated, iteration time = max arrival
  * async:       Alg. 1/2 discrete-event loop, staleness ~ N
  * softsync:    Zhang et al. (2015b) baseline, c arrivals per update
Same lr-per-datapoint rule as the paper (A.3) scaled to the tiny problem.
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import numpy as np

from benchmarks import common
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.data.synthetic_lm import SyntheticLMConfig
from repro.train.loop import run_experiment

# same stream parameters as common.tiny_lm_problem's held-out eval batches
_NOISE = 0.2


def _data_cfg(cfg: TrainConfig) -> SyntheticLMConfig:
    return SyntheticLMConfig(
        vocab_size=cfg.model.vocab_size, seq_len=cfg.shape.seq_len,
        global_batch=cfg.shape.global_batch,
        num_workers=cfg.aggregation.total_workers, seed=cfg.seed,
        noise=_NOISE)


def _variant_cfg(strategy: str, *, workers: int, backups: int = 0,
                 steps: int, lr: float, softsync_c: int = 1,
                 seed: int = 0) -> TrainConfig:
    total = workers + backups
    return TrainConfig(
        model=common.tiny_lm_config(),
        shape=ShapeConfig("bench", 32, 8 * total, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      backup_workers=backups,
                                      softsync_c=softsync_c),
        optimizer=OptimizerConfig(name="sgd", learning_rate=lr,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.0),
        checkpoint=CheckpointConfig(every_steps=0),
        seed=seed, total_steps=steps, log_every=10)


def _trajectory(res) -> Tuple[np.ndarray, np.ndarray]:
    return (np.array([m["sim_time"] for m in res.metrics]),
            np.array([m["loss"] for m in res.metrics]))


def run(quick: bool = True,
        steps: Optional[int] = None) -> List[Tuple[str, float, str]]:
    n, b = (6, 2) if quick else (12, 4)
    steps = steps or (250 if quick else 800)
    lr_sync = 0.08 * n            # paper A.3: lr scales with N
    lr_async = 0.08
    eps = 2.6
    rows = []
    # held-out eval on the same tiny-LM family (worker id 997 stream)
    _, _, _, _, eval_fn = common.tiny_lm_problem(batch=8, workers=n + b)

    t0 = time.time()
    cfg_b = _variant_cfg("backup", workers=n, backups=b, steps=steps,
                         lr=lr_sync)
    res_b = run_experiment(cfg_b, data_cfg=_data_cfg(cfg_b))
    times_b, losses_b = _trajectory(res_b)
    rows.append(("sync_vs_async.sync_backup",
                 (time.time() - t0) * 1e6 / steps,
                 f"final={eval_fn(res_b.params):.3f}"))

    t0 = time.time()
    cfg_f = _variant_cfg("full_sync", workers=n + b, steps=steps, lr=lr_sync)
    res_f = run_experiment(cfg_f, data_cfg=_data_cfg(cfg_f))
    times_f, losses_f = _trajectory(res_f)
    rows.append(("sync_vs_async.sync_full",
                 (time.time() - t0) * 1e6 / steps,
                 f"final={eval_fn(res_f.params):.3f}"))

    # async with the same machine count; one PS update per arrival, so run
    # enough updates to see the same number of gradient computations
    async_steps = steps * (n + b) // 2
    t0 = time.time()
    cfg_a = _variant_cfg("async", workers=n + b, steps=async_steps,
                         lr=lr_async)
    res_a = run_experiment(cfg_a, data_cfg=_data_cfg(cfg_a))
    final_async = eval_fn(res_a.params)
    rows.append(("sync_vs_async.async",
                 (time.time() - t0) * 1e6 / max(res_a.steps, 1),
                 f"final={final_async:.3f},mean_staleness="
                 f"{res_a.mean_staleness:.1f}"))

    # softsync baseline: average c=2 arrivals per (stale) update
    t0 = time.time()
    cfg_s = _variant_cfg("softsync", workers=n + b, steps=async_steps // 2,
                         lr=lr_async * 2, softsync_c=2)
    res_s = run_experiment(cfg_s, data_cfg=_data_cfg(cfg_s))
    rows.append(("sync_vs_async.softsync",
                 (time.time() - t0) * 1e6 / max(res_s.steps, 1),
                 f"final={eval_fn(res_s.params):.3f},mean_staleness="
                 f"{res_s.mean_staleness:.1f}"))

    t_sync = common.time_to_threshold(times_b, losses_b, eps)
    t_full = common.time_to_threshold(times_f, losses_f, eps)
    times_a, losses_a = _trajectory(res_a)
    t_async = common.time_to_threshold(times_a, losses_a, eps)

    better_final = eval_fn(res_b.params) <= final_async + 1e-3
    faster_than_full = (t_sync or np.inf) <= (t_full or np.inf)
    rows.append(("sync_vs_async.backup_better_final_than_async", 0.0,
                 str(bool(better_final))))
    rows.append(("sync_vs_async.backup_faster_than_fullsync", 0.0,
                 str(bool(faster_than_full))))
    common.save_json("sync_vs_async", {
        "N": n, "b": b, "steps": steps,
        # trajectories are TRAINING loss from the unified metrics stream
        # (the legacy bench logged held-out loss here); thresholds compare
        # all variants on the same train-loss footing
        "sync_backup": {"times": times_b.tolist(),
                        "train_losses": losses_b.tolist(),
                        "t_eps_train": t_sync,
                        "final_heldout": float(eval_fn(res_b.params)),
                        "mean_selected": res_b.mean_selected},
        "sync_full": {"times": times_f.tolist(),
                      "train_losses": losses_f.tolist(),
                      "t_eps_train": t_full,
                      "final_heldout": float(eval_fn(res_f.params)),
                      "mean_selected": res_f.mean_selected},
        "async": {"final_heldout": final_async, "t_eps_train": t_async,
                  "mean_staleness": res_a.mean_staleness,
                  "sim_time_total": res_a.sim_time},
        "softsync": {"final_heldout": eval_fn(res_s.params),
                     "mean_staleness": res_s.mean_staleness,
                     "sim_time_total": res_s.sim_time},
        "paper_claim": "Fig 8/9: Sync+backup converges faster and to better"
                       " test metric than Async; Async degrades with N",
    })
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
