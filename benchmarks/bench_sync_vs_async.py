"""Paper Figs. 8/9 (headline result): Sync-Opt with backup workers
converges FASTER (simulated wall time) and to a BETTER optimum than
Async-Opt at matched worker counts; plain Sync (b=0) is slowed by
stragglers.

Setup: tiny LM, N+b machines under the calibrated latency model.
  * async: Alg. 1/2 event simulation, staleness ~ N
  * sync_full: all N+b aggregated, iteration time = max arrival
  * sync_backup: first N of N+b aggregated (Alg. 3/4)
Same lr-per-datapoint rule as the paper (A.3) scaled to the tiny problem.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import async_sim, events, straggler
from repro.core.aggregation import BackupWorkers, FullSync


def _sync_run(strategy, n_agg: int, steps: int, lr: float, seed: int = 0):
    workers = strategy.total_workers
    model, params, grad_fn, batch_fn, eval_fn = common.tiny_lm_problem(
        batch=8, workers=workers, seed=seed)
    sim = events.StragglerSimulator(strategy, straggler.PaperCalibrated(),
                                    seed=seed)

    @jax.jit
    def masked_step(params, batches, mask):
        from repro.core import sync_backup
        def loss(p):
            per = []
            for b in batches:
                lt, aux = model.per_token_loss(p, b)
                per.append(lt.mean() + aux)
            per = jnp.stack(per)
            return jnp.sum(per * mask.astype(jnp.float32)) / n_agg
        l, g = jax.value_and_grad(loss)(params)
        return l, g

    t, losses, times = 0.0, [], []
    for step in range(steps):
        ev = sim.next_event()
        batches = [batch_fn(w, step) for w in range(workers)]
        _, grads = masked_step(params, batches, jnp.asarray(ev.mask))
        params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        t += ev.iteration_time
        if step % 10 == 0:
            losses.append(eval_fn(params))
            times.append(t)
    return np.array(times), np.array(losses), t


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    n, b = (6, 2) if quick else (12, 4)
    steps = 250 if quick else 800
    lr_sync = 0.08 * n            # paper A.3: lr scales with N
    lr_async = 0.08
    eps = 2.6
    rows, out = [], {}

    t0 = time.time()
    times_b, losses_b, _ = _sync_run(BackupWorkers(n, b), n, steps, lr_sync)
    rows.append(("sync_vs_async.sync_backup",
                 (time.time() - t0) * 1e6 / steps,
                 f"final={losses_b[-1]:.3f}"))

    t0 = time.time()
    times_f, losses_f, _ = _sync_run(FullSync(n + b), n + b, steps, lr_sync)
    rows.append(("sync_vs_async.sync_full",
                 (time.time() - t0) * 1e6 / steps,
                 f"final={losses_f[-1]:.3f}"))

    # async with the same machine count
    model, params, grad_fn, batch_fn, eval_fn = common.tiny_lm_problem(
        batch=8, workers=n + b, seed=0)
    update = common.sgd_update_fn(lr_async)
    t0 = time.time()
    res = async_sim.simulate_async(grad_fn, update, params, batch_fn,
                                   num_workers=n + b,
                                   num_updates=steps * (n + b) // 2,
                                   latency=straggler.PaperCalibrated(),
                                   seed=0)
    async_losses, async_times = [], []
    stride = max(1, len(res.losses) // 60)
    p = params
    # re-evaluate on held-out data along the async trajectory is costly;
    # use the recorded train losses (smoothed) + final held-out loss
    final_async = eval_fn(res.params)
    rows.append(("sync_vs_async.async",
                 (time.time() - t0) * 1e6 / max(res.updates, 1),
                 f"final={final_async:.3f},mean_staleness="
                 f"{res.staleness.mean():.1f}"))

    t_sync = common.time_to_threshold(times_b, losses_b, eps)
    t_full = common.time_to_threshold(times_f, losses_f, eps)
    smooth = np.convolve(res.losses, np.ones(25) / 25, mode="same")
    t_async = common.time_to_threshold(res.sim_time, smooth, eps)

    better_final = losses_b[-1] <= final_async + 1e-3
    faster_than_full = (t_sync or np.inf) <= (t_full or np.inf)
    rows.append(("sync_vs_async.backup_better_final_than_async", 0.0,
                 str(bool(better_final))))
    rows.append(("sync_vs_async.backup_faster_than_fullsync", 0.0,
                 str(bool(faster_than_full))))
    common.save_json("sync_vs_async", {
        "N": n, "b": b, "steps": steps,
        "sync_backup": {"times": times_b.tolist(), "losses": losses_b.tolist(),
                        "t_eps": t_sync},
        "sync_full": {"times": times_f.tolist(), "losses": losses_f.tolist(),
                      "t_eps": t_full},
        "async": {"final_heldout": final_async, "t_eps_train": t_async,
                  "mean_staleness": float(res.staleness.mean()),
                  "sim_time_total": float(res.sim_time[-1])},
        "paper_claim": "Fig 8/9: Sync+backup converges faster and to better"
                       " test metric than Async; Async degrades with N",
    })
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
