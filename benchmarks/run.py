"""Benchmark harness entry point: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract) and
writes per-bench JSON artifacts to experiments/bench/. Quick mode by
default; REPRO_BENCH_FULL=1 for the full-length runs recorded in
EXPERIMENTS.md.

  bench_straggler        — Figs. 3/4 (arrival order statistics)
  bench_staleness        — Fig. 2 / §2.1 (staleness degrades the optimum)
  bench_iterations_vs_n  — Fig. 5 (iterations vs N)
  bench_time_to_converge — Fig. 6 (optimal N/b split of 100 machines)
  bench_lr_sweep         — Table 2 / Fig. 7 (speed vs final-metric tradeoff)
  bench_sync_vs_async    — Figs. 8/9 (the headline comparison)
  bench_event_loop       — fused event engine vs per-arrival loop
  bench_spmd             — SPMD mesh engine vs simulated backend
  bench_recovery         — MTTR + chaos overhead of the recovery supervisor
  bench_serve            — continuous batching vs static at 3 offered loads
  bench_obs              — tracer overhead + perfmodel predicted-vs-measured
  bench_step_time        — host step-time microbenchmark per arch
  roofline               — §Roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import common


def main() -> None:
    quick = common.quick_mode()
    from benchmarks import (bench_event_loop, bench_iterations_vs_n,
                            bench_layer_staleness, bench_lr_sweep,
                            bench_obs, bench_recovery, bench_serve,
                            bench_spmd, bench_staleness, bench_step_time,
                            bench_straggler, bench_sync_vs_async,
                            bench_time_to_converge, roofline)
    modules = [
        ("straggler", bench_straggler),
        ("layer_staleness", bench_layer_staleness),
        ("iterations_vs_n", bench_iterations_vs_n),
        ("time_to_converge", bench_time_to_converge),
        ("staleness", bench_staleness),
        ("lr_sweep", bench_lr_sweep),
        ("sync_vs_async", bench_sync_vs_async),
        ("event_loop", bench_event_loop),
        ("spmd", bench_spmd),                  # re-execs itself (forced devices)
        ("recovery", bench_recovery),
        ("serve", bench_serve),
        ("obs", bench_obs),
        ("step_time", bench_step_time),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            for row in mod.run(quick=quick):
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"{name}.wall_s,{(time.time() - t0) * 1e6:.0f},total",
              file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
