"""Serving engine bench: open-loop latency/throughput vs offered load.

Replays a seeded synthetic arrival trace (repro.serve.trace) through the
continuous-batching engine at three offered loads — light, near-critical
and saturated — and reports tokens/s, p50/p99 request latency and page-
pool occupancy per load. At the saturated load the same trace is also
served two more ways:

* ``policy="static"`` — the same paged engine, but whole-batch-at-a-time
  admission (admit a full batch, drain it completely, repeat). This is
  the controlled comparison: identical kernels, only the scheduler
  differs, so the gap is pure head-of-line blocking (a finished short
  request's slot idles until the longest request in the batch drains).
* the toy path — the pre-serve ``launch/serve.py --toy`` discipline that
  this subsystem replaces: token-at-a-time prefill through jitted
  ``decode_step``, one contiguous bucketed cache, fixed whole-batch
  decode budget. This is the headline ``continuous_vs_static_tokens_per_s``
  baseline the acceptance bar names.

Loads are expressed as target utilisation ``rho`` and converted to
arrival rates using the *measured* decode-step time, so the bench means
the same thing on any host speed. Writes experiments/bench/
BENCH_serve.json + the repo-root headline mirror (docs/perf.md schema).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import write_bench

RHOS = (0.25, 1.0, 4.0)            # light / near-critical / saturated


def toy_static_run(model, params, trace, slots):
    """Replay ``trace`` with the toy discipline this subsystem replaces.

    Waves of ``slots`` requests: token-at-a-time prefill through jitted
    ``decode_step`` on one contiguous bucketed cache (short prompts
    right-padded to the wave max, as the toy padded its batch), then a
    whole-wave decode budget of max(max_new) steps. Open loop: a wave
    admits only requests that have already arrived. Timing-only baseline;
    each request is credited with the max_new tokens it asked for and
    finishes when its wave drains (the toy returned results per batch).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.train.serve_step import bucketed_max_len

    step = jax.jit(model.decode_step)
    reqs = sorted(trace, key=lambda r: (r.arrival, r.rid))
    cache_len = bucketed_max_len(max(r.prompt_len for r in reqs)
                                 + max(r.max_new for r in reqs) + 1)
    cache = model.init_cache(slots, cache_len)          # compile warmup
    tok = jnp.zeros((slots, 1), jnp.int32)
    logits, cache = step(params, tok, cache)
    jax.block_until_ready(logits)

    lat, total_tokens, i = [], 0, 0
    t0 = time.perf_counter()
    while i < len(reqs):
        now = time.perf_counter() - t0
        if reqs[i].arrival > now:
            time.sleep(reqs[i].arrival - now)
            now = reqs[i].arrival
        wave = [r for r in reqs[i:i + slots] if r.arrival <= now]
        wave = wave or [reqs[i]]
        i += len(wave)
        plen = max(r.prompt_len for r in wave)
        prompts = np.zeros((slots, plen), np.int32)
        for j, r in enumerate(wave):
            prompts[j, :r.prompt_len] = r.prompt
        cache = model.init_cache(slots, cache_len)
        logits = None
        for t in range(plen):
            logits, cache = step(params, prompts[:, t:t + 1], cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(max(r.max_new for r in wave) - 1):
            logits, cache = step(params, tok, cache)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        end = time.perf_counter() - t0
        for r in wave:
            lat.append(end - r.arrival)
            total_tokens += r.max_new
    duration = max((time.perf_counter() - t0) - reqs[0].arrival, 1e-9)
    return {
        "policy": "toy", "tokens_per_s": total_tokens / duration,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "completed": len(lat),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="short trace (CI canary settings)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--cache-int8", action="store_true")
    args = ap.parse_args(argv)
    quick = args.quick
    requests = args.requests or (32 if quick else 64)
    slots = 8
    max_new = 16 if quick else 32

    import jax
    import numpy as np
    from repro import configs
    from repro.models import get_model
    from repro.serve import ServeEngine, TraceConfig, make_trace

    cfg = configs.get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, num_slots=slots, page_size=8,
                         max_prompt_len=16, max_new_cap=max_new,
                         cache_int8=args.cache_int8)

    def trace_cfg(rate, seed=0):
        # wide max_new spread: static batching pays E[max]/E[mean] per
        # batch in head-of-line blocking, which is the effect under test
        return TraceConfig(num_requests=requests, rate=rate,
                           prompt_len_min=2, prompt_len_max=16,
                           max_new_min=2, max_new_max=max_new,
                           vocab=cfg.vocab_size, seed=seed)

    # warm every bucket + the decode step so no arm pays first-compile
    engine.run(make_trace(trace_cfg(1e9, seed=7)))

    # calibrate: decode-step seconds at full slots -> machine-independent
    # arrival rates.  rho = rate * E[service time] / slots
    t0 = time.perf_counter()
    sat = engine.run(make_trace(trace_cfg(1e9, seed=7)))
    t_step = (time.perf_counter() - t0) / max(sat.metrics["decode_steps"], 1)
    mean_new = (2 + max_new) / 2.0
    crit_rate = slots / (mean_new * t_step)

    results = []
    for rho in RHOS:
        rate = rho * crit_rate
        rep = engine.run(make_trace(trace_cfg(rate)), policy="continuous")
        m = rep.metrics
        results.append({
            "policy": "continuous", "rho": rho, "offered_rate": rate,
            "tokens_per_s": m["tokens_per_s"],
            "p50_latency_s": m["p50_latency"],
            "p99_latency_s": m["p99_latency"],
            "p50_ttft_s": m["p50_ttft"],
            "mean_occupancy": m["mean_occupancy"],
            "completed": m["completed"],
            "decode_steps": m["decode_steps"],
        })
        print(f"continuous rho={rho:<4} rate={rate:7.1f}/s "
              f"tok/s {m['tokens_per_s']:8.1f} p50 {m['p50_latency']:.3f}s "
              f"p99 {m['p99_latency']:.3f}s occ {m['mean_occupancy']:.2f}")
    peak_rate = RHOS[-1] * crit_rate
    rep_static = engine.run(make_trace(trace_cfg(peak_rate)),
                            policy="static")
    ms = rep_static.metrics
    results.append({
        "policy": "static", "rho": RHOS[-1], "offered_rate": peak_rate,
        "tokens_per_s": ms["tokens_per_s"],
        "p50_latency_s": ms["p50_latency"],
        "p99_latency_s": ms["p99_latency"],
        "p50_ttft_s": ms["p50_ttft"],
        "mean_occupancy": ms["mean_occupancy"],
        "completed": ms["completed"],
        "decode_steps": ms["decode_steps"],
    })
    print(f"static     rho={RHOS[-1]:<4} rate={peak_rate:7.1f}/s "
          f"tok/s {ms['tokens_per_s']:8.1f} p50 {ms['p50_latency']:.3f}s "
          f"p99 {ms['p99_latency']:.3f}s occ {ms['mean_occupancy']:.2f}")
    toy = toy_static_run(model, params, make_trace(trace_cfg(peak_rate)),
                         slots)
    toy["rho"] = RHOS[-1]
    toy["offered_rate"] = peak_rate
    results.append(toy)
    print(f"toy        rho={RHOS[-1]:<4} rate={peak_rate:7.1f}/s "
          f"tok/s {toy['tokens_per_s']:8.1f} p50 {toy['p50_latency_s']:.3f}s "
          f"p99 {toy['p99_latency_s']:.3f}s")

    peak = results[len(RHOS) - 1]
    ratio = peak["tokens_per_s"] / max(toy["tokens_per_s"], 1e-9)
    ratio_engine = peak["tokens_per_s"] / max(ms["tokens_per_s"], 1e-9)
    payload = {
        "bench": "serve",
        "model": "qwen3-0.6b smoke",
        "slots": slots,
        "page_size": engine.pool_cfg.page_size,
        "num_pages": engine.pool_cfg.num_pages,
        "requests": requests,
        "cache": "int8" if args.cache_int8 else cfg.dtype,
        "loads": [r * crit_rate for r in RHOS],
        "results": results,
        "continuous_vs_static_tokens_per_s": ratio,
        "continuous_vs_engine_static_tokens_per_s": ratio_engine,
        "tokens_per_s_peak": peak["tokens_per_s"],
        "p99_latency_s_peak": peak["p99_latency_s"],
        "prefill_compiles": engine.prefill_compiles,
        "decode_compiles": engine.decode_compiles,
    }
    mirror = {
        "bench": "serve", "slots": slots,
        "loads": payload["loads"],
        "tokens_per_s_peak": payload["tokens_per_s_peak"],
        "p99_latency_s_peak": payload["p99_latency_s_peak"],
        "continuous_vs_static_tokens_per_s": ratio,
    }
    path = write_bench("BENCH_serve", payload, mirror=mirror)
    print(f"continuous vs toy static at peak load: {ratio:.2f}x tokens/s "
          f"(vs engine-static: {ratio_engine:.2f}x) -> {path} "
          f"(+ root BENCH_serve.json)")
    return payload


def run(quick: bool = True):
    """benchmarks/run.py harness contract: (name, us_per_call, derived)."""
    payload = main(["--quick"] if quick else [])
    return [
        ("serve.tokens_per_s_peak", 0.0,
         f"{payload['tokens_per_s_peak']:.1f}tok/s"),
        ("serve.p99_latency_peak",
         payload["p99_latency_s_peak"] * 1e6,
         f"{payload['p99_latency_s_peak']:.3f}s"),
        ("serve.continuous_vs_static", 0.0,
         f"{payload['continuous_vs_static_tokens_per_s']:.2f}x"),
    ]


if __name__ == "__main__":
    main()
