"""SPMD execution engine vs the single-device simulated backend.

Measures wall-clock steps/s of the tiny-LM backup-worker rig for
W in {4, 8} workers, chunk_size in {1, 32}, and mesh_model in {1, 2},
on both execution backends: 'sim' (one device, workers as loop index)
and 'spmd' (the repro.distributed.spmd_engine — workers over a real
mesh 'data' axis with mesh_data = W, masked aggregation as an in-shard
backup_reduce + psum collective; docs/spmd.md). mesh_model = 2
additionally shards params / optimizer state / EMA over the mesh
'model' axis and computes each worker's gradient tensor-parallel
(explicit psums at the contracted dims) — the TP overhead relative to
the replicated mesh_model = 1 engine is the new quantity this benchmark
tracks.

The process forces 16 host platform devices (the (W=8, M=2) cell), so
on CPU hosts every "device" is a slice of the same machine and the
ratios reported here measure the ENGINE'S overhead (shard_map
partitioning, the collectives, the interpret-mode Pallas reduce), not a
speedup — the win appears on real accelerators where the per-worker
gradients (and, under TP, each gradient's matmuls) genuinely
parallelize. Tracking the overhead ratio per commit is the point: it is
the price of mesh execution at a given (W, K, M), and regressions here
are regressions on real hardware too.

Writes experiments/bench/BENCH_spmd.json and mirrors the headline
summary to the repo-root BENCH_spmd.json.
"""
from __future__ import annotations

import os

# must precede ANY jax import in this process (common.py imports jax).
# The (W=8, mesh_model=2) cell needs 16 devices: raise any pre-existing
# forced count below that (e.g. the 8 every doc example exports) instead
# of inheriting it and crashing mid-run at the m=2 cells.
import re as _re

_FORCED = "--xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
_m = _re.search(_re.escape(_FORCED) + r"=(\d+)", _flags)
if _m is None:
    os.environ["XLA_FLAGS"] = (_flags + f" {_FORCED}=16").strip()
elif int(_m.group(1)) < 16:
    os.environ["XLA_FLAGS"] = _flags.replace(_m.group(0), f"{_FORCED}=16")

import argparse
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import write_bench

WORKER_COUNTS = (4, 8)
CHUNK_SIZES = (1, 32)
MESH_MODELS = (1, 2)


def build_trainer(backend: str, workers: int, chunk_size: int,
                  mesh_model: int = 1):
    from repro import configs
    from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                    ExecutionConfig, OptimizerConfig,
                                    ShapeConfig, TrainConfig, replace)
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    # tiny model, small shape: the measurement isolates the execution
    # machinery (dispatch, partitioning, collectives), not model FLOPs.
    # Dims are chosen divisible by mesh_model=2 so the TP cells shard.
    model = replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=64, vocab_pad_multiple=16)
    cfg = TrainConfig(
        model=model,
        shape=ShapeConfig("bench", 16, 2 * workers, "train"),
        aggregation=AggregationConfig(strategy="backup",
                                      num_workers=workers - 1,
                                      backup_workers=1),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.02,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.999),
        checkpoint=CheckpointConfig(every_steps=0),
        execution=ExecutionConfig(backend=backend, mesh_data=workers,
                                  mesh_model=mesh_model),
        log_every=1, chunk_size=chunk_size)
    tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    tr.init_state()
    return tr


def measure_all(specs, steps: int, reps: int = 3):
    """Build+compile every config first, then interleave the timed reps
    so CPU thermal drift doesn't systematically penalize whichever
    config is measured last."""
    trainers = []
    for backend, workers, chunk, mesh_model in specs:
        tr = build_trainer(backend, workers, chunk, mesh_model)
        tr.run(max(chunk, 8))                      # compile + warm caches
        trainers.append(tr)
    best = [None] * len(specs)
    for _ in range(reps):
        for i, tr in enumerate(trainers):
            t0 = time.perf_counter()
            tr.run(steps)
            dt = time.perf_counter() - t0
            best[i] = dt if best[i] is None or dt < best[i] else best[i]
    return [{"backend": b, "workers": w, "chunk_size": c, "mesh_model": m,
             "steps": steps, "wall_s": wall, "steps_per_s": steps / wall}
            for (b, w, c, m), wall in zip(specs, best)]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed steps (CI)")
    args = ap.parse_args(argv)

    steps = 32 if args.quick else 96
    specs = [("sim", w, c, 1) for w in WORKER_COUNTS for c in CHUNK_SIZES]
    specs += [("spmd", w, c, m) for w in WORKER_COUNTS for c in CHUNK_SIZES
              for m in MESH_MODELS]
    results = measure_all(specs, steps)

    def rate(backend, workers, chunk, mesh_model):
        return next(r["steps_per_s"] for r in results
                    if r["backend"] == backend and r["workers"] == workers
                    and r["chunk_size"] == chunk
                    and r["mesh_model"] == mesh_model)

    # spmd/sim per cell: < 1 on forced CPU devices (engine overhead),
    # the quantity to keep from regressing; the m2 cells price the
    # tensor-parallel collectives on top of the worker-mesh machinery
    ratios = {f"spmd_vs_sim_w{w}_chunk{c}_m{m}":
              rate("spmd", w, c, m) / rate("sim", w, c, 1)
              for w in WORKER_COUNTS for c in CHUNK_SIZES
              for m in MESH_MODELS}
    payload = {
        "bench": "spmd",
        "model": "qwen3-0.6b tiny (1L, d32)",
        "devices_forced": 16,
        "mesh_models": list(MESH_MODELS),
        "steps": steps,
        "results": results,
        **ratios,
    }
    path = write_bench("BENCH_spmd", payload,
                       mirror={"bench": "spmd", **ratios})
    for r in results:
        print(f"backend={r['backend']:<5} W={r['workers']} "
              f"chunk={r['chunk_size']:>3} m={r['mesh_model']} "
              f"{r['steps_per_s']:8.1f} steps/s")
    for k, v in ratios.items():
        print(f"{k}: {v:.3f}")
    print(f"-> {path} (+ root BENCH_spmd.json)")
    return payload


def run(quick: bool = True):
    """benchmarks/run.py harness contract: (name, us_per_call, derived).

    Executed in a fresh subprocess: the forced host device count must be
    set before jax initializes, which the harness process already did.
    """
    import json
    cmd = [sys.executable, os.path.abspath(__file__)]
    if quick:
        cmd.append("--quick")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)        # let the module force its own devices
    subprocess.run(cmd, check=True, env=env,
                   cwd=os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench", "BENCH_spmd.json")) as f:
        payload = json.load(f)
    rows = [(f"spmd.{r['backend']}_w{r['workers']}_chunk{r['chunk_size']}"
             f"_m{r['mesh_model']}",
             1e6 / r["steps_per_s"], f"{r['steps_per_s']:.1f}steps/s")
            for r in payload["results"]]
    rows += [(f"spmd.{k}", 0.0, f"{v:.3f}x")
             for k, v in payload.items() if k.startswith("spmd_vs_sim")]
    return rows


if __name__ == "__main__":
    main()
