"""SPMD execution engine vs the single-device simulated backend.

Measures wall-clock steps/s of the tiny-LM backup-worker rig for
W in {4, 8} workers, chunk_size in {1, 32}, and mesh_model in {1, 2},
on both execution backends: 'sim' (one device, workers as loop index)
and 'spmd' (the repro.distributed.spmd_engine — workers over a real
mesh 'data' axis with mesh_data = W, masked aggregation as an in-shard
backup_reduce + psum collective; docs/spmd.md). mesh_model = 2
additionally shards params / optimizer state / EMA over the mesh
'model' axis and computes each worker's gradient tensor-parallel
(explicit psums at the contracted dims) — the TP overhead relative to
the replicated mesh_model = 1 engine is the new quantity this benchmark
tracks.

The process forces 16 host platform devices (the (W=8, M=2) cell), so
on CPU hosts every "device" is a slice of the same machine and the
ratios reported here measure the ENGINE'S overhead (shard_map
partitioning, the collectives, the interpret-mode Pallas reduce), not a
speedup — the win appears on real accelerators where the per-worker
gradients (and, under TP, each gradient's matmuls) genuinely
parallelize. Tracking the overhead ratio per commit is the point: it is
the price of mesh execution at a given (W, K, M), and regressions here
are regressions on real hardware too.

Each cell also reports a BYTES-MOVED axis: per-step collective wire
traffic parsed from the optimized HLO (launch.dryrun.parse_collectives,
while-loop bodies multiplied by trip count) — after the fused bucketed
reduce-then-psum rework one psum per bucket carries gradient plus
monitoring scalars, so the axis makes the collective-count win (3 ->
1 per step at bucket_size=0) directly visible next to the wall-clock.

Writes experiments/bench/BENCH_spmd.json and mirrors the headline
summary to the repo-root BENCH_spmd.json.
"""
from __future__ import annotations

import os

# must precede ANY jax import in this process (common.py imports jax).
# The (W=8, mesh_model=2) cell needs 16 devices: raise any pre-existing
# forced count below that (e.g. the 8 every doc example exports) instead
# of inheriting it and crashing mid-run at the m=2 cells.
import re as _re

_FORCED = "--xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
_m = _re.search(_re.escape(_FORCED) + r"=(\d+)", _flags)
if _m is None:
    os.environ["XLA_FLAGS"] = (_flags + f" {_FORCED}=16").strip()
elif int(_m.group(1)) < 16:
    os.environ["XLA_FLAGS"] = _flags.replace(_m.group(0), f"{_FORCED}=16")

import argparse
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import write_bench

WORKER_COUNTS = (4, 8)
CHUNK_SIZES = (1, 32)
MESH_MODELS = (1, 2)


def build_trainer(backend: str, workers: int, chunk_size: int,
                  mesh_model: int = 1, tracer=None, metrics=None):
    from repro import configs
    from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                    ExecutionConfig, OptimizerConfig,
                                    ShapeConfig, TrainConfig, replace)
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    # tiny model, small shape: the measurement isolates the execution
    # machinery (dispatch, partitioning, collectives), not model FLOPs.
    # Dims are chosen divisible by mesh_model=2 so the TP cells shard.
    model = replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=64, vocab_pad_multiple=16)
    cfg = TrainConfig(
        model=model,
        shape=ShapeConfig("bench", 16, 2 * workers, "train"),
        aggregation=AggregationConfig(strategy="backup",
                                      num_workers=workers - 1,
                                      backup_workers=1),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.02,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.999),
        checkpoint=CheckpointConfig(every_steps=0),
        execution=ExecutionConfig(backend=backend, mesh_data=workers,
                                  mesh_model=mesh_model),
        log_every=1, chunk_size=chunk_size)
    tr = Trainer(cfg, latency=Uniform(1.0, 2.0), tracer=tracer,
                 metrics=metrics)
    tr.init_state()
    return tr


def collective_bytes_per_step(tr) -> dict:
    """The bytes-moved axis: lower the trainer's installed step (the
    chunked scan when chunk_size > 1), parse the optimized HLO with
    ``launch.dryrun.parse_collectives`` (while-loop bodies multiplied by
    trip count), and report per-STEP collective traffic. 'sim' cells are
    single-device and report zeros — the axis prices exactly what the
    mesh engine puts on the wire (one fused psum per bucket after the
    bucketed reduce-then-psum rework; docs/spmd.md)."""
    import jax.numpy as jnp

    from repro.launch.dryrun import parse_collectives

    cfg = tr.cfg
    K = cfg.chunk_size
    B, S = cfg.shape.global_batch, cfg.shape.seq_len
    W = cfg.aggregation.total_workers
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    if K > 1:
        stack = {k: jnp.zeros((K,) + v.shape, v.dtype)
                 for k, v in batch.items()}
        lowered = tr.chunk_step.lower(
            tr.params, tr.opt_state, tr.ema, jnp.int32(0), stack,
            jnp.ones((K, W), jnp.float32))
    else:
        lowered = tr.train_step.lower(
            tr.params, tr.opt_state, tr.ema, jnp.int32(0), batch,
            jnp.ones((W,), jnp.float32))
    coll = parse_collectives(lowered.compile().as_text())
    return {"collective_bytes_per_step": coll["total_wire_bytes"] / K,
            "collective_ops_per_step": coll["num_ops"] / K}


def measure_all(specs, steps: int, reps: int = 5, tracer=None, metrics=None):
    """Build+compile every config first, then interleave the timed reps
    so CPU thermal drift doesn't systematically penalize whichever
    config is measured last. Best-of-5 per config: the fast chunk=1
    cells step in ~3ms, so best-of-3 still carries visible scheduler
    noise into the ratios. The bytes-moved axis is read from each
    trainer's lowered HLO after the timed reps (untimed)."""
    trainers = []
    for backend, workers, chunk, mesh_model in specs:
        tr = build_trainer(backend, workers, chunk, mesh_model,
                           tracer=tracer, metrics=metrics)
        tr.run(max(chunk, 8))                      # compile + warm caches
        trainers.append(tr)
    best = [None] * len(specs)
    for _ in range(reps):
        for i, tr in enumerate(trainers):
            t0 = time.perf_counter()
            tr.run(steps)
            dt = time.perf_counter() - t0
            best[i] = dt if best[i] is None or dt < best[i] else best[i]
    return [{"backend": b, "workers": w, "chunk_size": c, "mesh_model": m,
             "steps": steps, "wall_s": wall, "steps_per_s": steps / wall,
             **collective_bytes_per_step(tr)}
            for (b, w, c, m), wall, tr in zip(specs, best, trainers)]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed steps (CI)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record host-side spans across every measured "
                         "trainer and export a Chrome trace here (adds "
                         "dispatch fences — numbers will be slower)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="dump the unified metrics registry as JSONL here")
    ap.add_argument("--platform", default=None, choices=("cpu", "gpu"),
                    help="pin the jax platform and apply its XLA flag "
                         "recipe (gpu: the latency-hiding flags the "
                         "bucketed psum overlap is shaped for)")
    args = ap.parse_args(argv)
    if args.platform:
        from repro.launch import mesh as mesh_lib
        added = mesh_lib.set_platform(args.platform)
        if added:
            print(f"[bench_spmd] XLA flags: {' '.join(added)}")
    tracer = metrics = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()

    steps = 32 if args.quick else 96
    specs = [("sim", w, c, 1) for w in WORKER_COUNTS for c in CHUNK_SIZES]
    specs += [("spmd", w, c, m) for w in WORKER_COUNTS for c in CHUNK_SIZES
              for m in MESH_MODELS]
    results = measure_all(specs, steps, tracer=tracer, metrics=metrics)

    def rate(backend, workers, chunk, mesh_model):
        return next(r["steps_per_s"] for r in results
                    if r["backend"] == backend and r["workers"] == workers
                    and r["chunk_size"] == chunk
                    and r["mesh_model"] == mesh_model)

    # spmd/sim per cell: < 1 on forced CPU devices (engine overhead),
    # the quantity to keep from regressing; the m2 cells price the
    # tensor-parallel collectives on top of the worker-mesh machinery
    ratios = {f"spmd_vs_sim_w{w}_chunk{c}_m{m}":
              rate("spmd", w, c, m) / rate("sim", w, c, 1)
              for w in WORKER_COUNTS for c in CHUNK_SIZES
              for m in MESH_MODELS}
    # bytes-moved axis: per-step collective wire traffic of each spmd
    # cell (sim cells are single-device, identically zero)
    bytes_moved = {
        f"spmd_bytes_per_step_w{r['workers']}_chunk{r['chunk_size']}"
        f"_m{r['mesh_model']}": r["collective_bytes_per_step"]
        for r in results if r["backend"] == "spmd"}
    payload = {
        "bench": "spmd",
        "model": "qwen3-0.6b tiny (1L, d32)",
        "devices_forced": 16,
        "mesh_models": list(MESH_MODELS),
        "steps": steps,
        "results": results,
        **ratios,
        **bytes_moved,
    }
    path = write_bench("BENCH_spmd", payload,
                       mirror={"bench": "spmd", **ratios, **bytes_moved})
    for r in results:
        print(f"backend={r['backend']:<5} W={r['workers']} "
              f"chunk={r['chunk_size']:>3} m={r['mesh_model']} "
              f"{r['steps_per_s']:8.1f} steps/s "
              f"{r['collective_bytes_per_step'] / 1024:8.1f} KiB/step "
              f"({r['collective_ops_per_step']:.0f} colls)")
    for k, v in ratios.items():
        print(f"{k}: {v:.3f}")
    if tracer is not None:
        tracer.export(args.trace)
        print(f"[bench_spmd] trace: {args.trace} ({len(tracer)} events)")
    if metrics is not None:
        metrics.dump_jsonl(args.metrics)
        print(f"[bench_spmd] metrics: {args.metrics} "
              f"({len(metrics)} series)")
    print(f"-> {path} (+ root BENCH_spmd.json)")
    return payload


def run(quick: bool = True):
    """benchmarks/run.py harness contract: (name, us_per_call, derived).

    Executed in a fresh subprocess: the forced host device count must be
    set before jax initializes, which the harness process already did.
    Trace / metrics / platform requests reach the child through the
    ``REPRO_BENCH_TRACE`` / ``REPRO_BENCH_METRICS`` /
    ``REPRO_BENCH_PLATFORM`` env vars (the ``run(quick)`` signature is
    fixed by the harness), forwarded as the child's own CLI flags.
    """
    import json
    cmd = [sys.executable, os.path.abspath(__file__)]
    if quick:
        cmd.append("--quick")
    for var, flag in (("REPRO_BENCH_TRACE", "--trace"),
                      ("REPRO_BENCH_METRICS", "--metrics"),
                      ("REPRO_BENCH_PLATFORM", "--platform")):
        val = os.environ.get(var)
        if val:
            cmd += [flag, val]
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)        # let the module force its own devices
    subprocess.run(cmd, check=True, env=env,
                   cwd=os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench", "BENCH_spmd.json")) as f:
        payload = json.load(f)
    rows = [(f"spmd.{r['backend']}_w{r['workers']}_chunk{r['chunk_size']}"
             f"_m{r['mesh_model']}",
             1e6 / r["steps_per_s"], f"{r['steps_per_s']:.1f}steps/s")
            for r in payload["results"]]
    rows += [(f"spmd.{k}", 0.0, f"{v:.3f}x")
             for k, v in payload.items() if k.startswith("spmd_vs_sim")]
    return rows


if __name__ == "__main__":
    main()
