"""Host/dispatch overhead of the EVENT training loop: legacy vs fused.

Measures updates/s of the qwen3-0.6b smoke config (CPU-sized) for the
async and softsync regimes at chunk_size in {1, 8, 32}. chunk_size=1 is
the legacy per-arrival path — per gradient arrival it pays one grad-fn
jit dispatch, one update-fn dispatch, a host heap pop/push, and a
metrics float() sync; larger chunks run the fused event engine: the host
plans a block of arrivals into flat arrays and a single lax.scan runs
gradients, strategy application, optimizer and EMA on device
(docs/perf.md "Event engine"). On smoke-scale models the per-arrival
Python/dispatch overhead dominates, so this ratio tracks exactly the
overhead the fused engine retires.

Writes experiments/bench/BENCH_events.json and mirrors the headline
summary (speedup_32_vs_1 for async — the acceptance metric) to the
repo-root BENCH_events.json for the perf-trajectory tooling.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import write_bench

CHUNK_SIZES = (1, 8, 32)
STRATEGIES = ("async", "softsync")


def build_trainer(strategy: str, chunk_size: int, workers: int = 4):
    from repro import configs
    from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                    OptimizerConfig, ShapeConfig, TrainConfig)
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    # smoke model, small shape: per-arrival device compute is a few ms, so
    # the measurement isolates the event loop's host/dispatch overhead
    # (the thing this benchmark exists to track), not model FLOPs
    cfg = TrainConfig(
        model=configs.get_smoke_config("qwen3-0.6b"),
        shape=ShapeConfig("bench", 8, 2 * workers, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      softsync_c=2),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.02,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.999),
        checkpoint=CheckpointConfig(every_steps=0),
        # per-update logging, as in real training: the legacy path pays a
        # metrics float() sync per update; the fused engine reads the whole
        # chunk's losses back in one go
        log_every=1,
        chunk_size=chunk_size)
    tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    tr.init_state()
    return tr


def measure_all(specs, updates: int, reps: int = 3):
    """Build+compile every config first, then interleave the timed reps
    (cfg0, cfg1, ..., cfg0, cfg1, ...) so CPU thermal drift doesn't
    systematically penalize whichever config is measured last."""
    trainers = []
    for strategy, chunk_size in specs:
        tr = build_trainer(strategy, chunk_size)
        tr.run(max(chunk_size, 8))                 # compile + warm caches
        trainers.append(tr)
    best = [None] * len(specs)
    for _ in range(reps):
        for i, tr in enumerate(trainers):
            t0 = time.perf_counter()
            tr.run(updates)
            dt = time.perf_counter() - t0
            best[i] = dt if best[i] is None or dt < best[i] else best[i]
    return [{"strategy": s, "chunk_size": c, "updates": updates,
             "wall_s": w, "updates_per_s": updates / w}
            for (s, c), w in zip(specs, best)]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed updates (CI)")
    args = ap.parse_args(argv)

    updates = 64 if args.quick else 192
    specs = [(s, c) for s in STRATEGIES for c in CHUNK_SIZES]
    results = measure_all(specs, updates)

    def rate(strategy, chunk):
        return next(r["updates_per_s"] for r in results
                    if r["strategy"] == strategy and r["chunk_size"] == chunk)

    def speedups(strategy):
        base = rate(strategy, 1)
        return {f"speedup_{c}_vs_1": rate(strategy, c) / base
                for c in CHUNK_SIZES if c > 1}

    per_strategy = {s: speedups(s) for s in STRATEGIES}
    payload = {
        "bench": "event_loop",
        "model": "qwen3-0.6b smoke",
        "updates": updates,
        "results": results,
        **{s: per_strategy[s] for s in STRATEGIES},
        # headline / acceptance metric: fused async vs the legacy
        # per-arrival loop (the bar for this repo is >= 3 on CPU)
        "speedup_32_vs_1": per_strategy["async"]["speedup_32_vs_1"],
    }
    mirror = {"bench": "event_loop",
              "speedup_32_vs_1": payload["speedup_32_vs_1"],
              **{s: per_strategy[s] for s in STRATEGIES},
              "legacy_updates_per_s": {s: rate(s, 1) for s in STRATEGIES}}
    path = write_bench("BENCH_events", payload, mirror=mirror)

    for r in results:
        print(f"strategy={r['strategy']:<9} chunk_size={r['chunk_size']:>3} "
              f"{r['updates_per_s']:8.1f} updates/s")
    print(f"async speedup 32 vs 1: {payload['speedup_32_vs_1']:.2f}x "
          f"-> {path} (+ root BENCH_events.json)")
    return payload


def run(quick: bool = True):
    """benchmarks/run.py harness contract: (name, us_per_call, derived)."""
    payload = main(["--quick"] if quick else [])
    rows = [(f"event_loop.{r['strategy']}_chunk{r['chunk_size']}",
             1e6 / r["updates_per_s"], f"{r['updates_per_s']:.1f}up/s")
            for r in payload["results"]]
    rows.append(("event_loop.async_speedup_32_vs_1", 0.0,
                 f"{payload['speedup_32_vs_1']:.2f}x"))
    return rows


if __name__ == "__main__":
    main()
